//! Fleet-scaling sweep: eager vs lazy park-ledger round throughput,
//! 10³ → 10⁶ devices (the PR 6 tentpole's proof-of-win).
//!
//! Uses the struct-of-arrays `ParkLedger` — the power half of a device
//! at ~250 bytes — so million-device fleets fit in memory. Each round
//! selects a small S(k), bills their training externally, and advances
//! the fleet clock: the eager ledger sweeps all n devices, the lazy
//! ledger steps O(selected) and defers the rest behind one window-log
//! push. Reported per fleet size: rounds/sec for both modes, the
//! speedup, and bytes/device.
//!
//! A second sweep drives the *full engine* — `Federation::run` over the
//! columnar fleet store (`--fleet columnar --ledger lazy`), so probe,
//! selection, training, hydration and billing are all on the clock —
//! and reports engine rounds/sec per fleet size (`engine_rps_1e6` in
//! the JSON at the 10⁶-device point).
//!
//! A third sweep times the *settle* itself — the observation-time wall
//! the lazy ledger defers to: after R pending rounds,
//! `ParkLedger::par_settle(k)` fast-forwards the whole fleet on k
//! scoped workers (k ∈ {1, 2, 4, 8}; devices settled per wall second,
//! best worker count reported as `settle_rps_1e6` at the 10⁶-device
//! point). Serial/parallel bit-identity is asserted in-bench before
//! any timing.
//!
//!     cargo bench --bench fleet_scaling
//!
//! Env:
//!   DEAL_BENCH_FAST=1       small fleets + short budgets (CI smoke)
//!   DEAL_BENCH_JSON=path    write machine-readable results
//!   DEAL_BENCH_BASELINE=p   compare lazy rounds/sec at 10⁴ devices to
//!                           a committed BENCH_fastforward.json; exits 1
//!                           on a >20% regression when the baseline was
//!                           actually measured ("measured": true)

mod common;

use std::time::{Duration, Instant};

use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::transport::{ClockTick, LedgerMode};
use deal::coordinator::{Federation, FleetStoreKind, ParkLedger, Scheme};
use deal::data::Dataset;
use deal::power::profile::table1_profiles;
use deal::power::FleetMode;
use deal::util::bench::{json_f64, write_results_json, BenchResult};

/// Allowed slowdown vs the committed baseline before the smoke fails.
const REGRESSION_FRAC: f64 = 0.20;
/// The fleet size the regression gate is pinned at.
const GATE_N: usize = 10_000;

fn fast() -> bool {
    std::env::var("DEAL_BENCH_FAST").as_deref() == Ok("1")
}

fn build_ledger(n: usize, mode: LedgerMode) -> ParkLedger {
    let profiles = table1_profiles();
    let mut l = ParkLedger::new(&profiles, n, mode);
    // every 8th device charges — enough to exercise the ChargePlan walk
    // in both modes without dominating the floor-billing cost
    for i in (0..n).step_by(8) {
        l.enable_charging(i, 0xFEED ^ i as u64);
    }
    l
}

/// One federated round against the ledger: select m devices
/// round-robin, bill their training, advance the clock.
fn run_round(l: &mut ParkLedger, round: usize, m: usize) {
    let n = l.n_devices();
    let mut selected: Vec<usize> = (0..m).map(|j| (round * m + j) % n).collect();
    selected.sort_unstable();
    selected.dedup();
    for &i in &selected {
        l.begin_training(i);
        l.add_busy(i, 3.0);
        l.drain(i, 500.0);
    }
    let tick = ClockTick { dt_s: 60.0, mode: FleetMode::DealSleep };
    l.advance_clock(tick, &selected);
}

/// Time-boxed throughput: rounds completed per wall second.
fn measure(n: usize, mode: LedgerMode, budget: Duration) -> (f64, usize) {
    let m = (n / 1000).clamp(4, 64);
    let mut l = build_ledger(n, mode);
    // one unmeasured round warms the columns
    run_round(&mut l, 0, m);
    let t0 = Instant::now();
    let mut rounds = 0usize;
    while t0.elapsed() < budget || rounds < 2 {
        run_round(&mut l, rounds + 1, m);
        rounds += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    if mode == LedgerMode::Lazy {
        // settle outside the measured window, but report it: deferred
        // windows are not free, they are amortized to the stats read
        let s0 = Instant::now();
        l.settle_all();
        println!(
            "    settle_all(n={n}) after {rounds} rounds: {:.1} ms",
            s0.elapsed().as_secs_f64() * 1e3
        );
    }
    (rounds as f64 / elapsed, rounds)
}

/// A full federation over the columnar fleet store: the acceptance
/// configuration (`deal run --fleet columnar --ledger lazy`) at fleet
/// size n. Mnist is the big-fleet dataset; m scales like the
/// ledger-only sweep so the hydrated working set stays comparable.
fn build_engine(n: usize) -> Federation {
    fleet::build(&FleetConfig {
        n_devices: n,
        dataset: Dataset::Mnist,
        scale: 0.05,
        scheme: Scheme::Deal,
        m: (n / 1000).clamp(4, 64),
        seed: 7,
        charging: true,
        round_period_s: 60.0,
        ledger: LedgerMode::Lazy,
        fleet: FleetStoreKind::Columnar,
        ..FleetConfig::default()
    })
}

/// Pull `"key": <number>` out of a JSON document (hand-rolled — the
/// crate is dependency-free, and the baseline schema is ours).
fn json_number(doc: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let at = doc.find(&pat)? + pat.len();
    let rest = doc[at..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || ".-+eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn main() {
    common::banner(
        "fleet scaling — lazy analytic fast-forward vs eager per-tick ledger",
        "a round should cost O(selected + woken), not O(n_devices)",
    );
    let fleets: &[usize] = if fast() {
        &[1_000, 10_000]
    } else {
        &[1_000, 10_000, 100_000, 1_000_000]
    };
    let budget = if fast() {
        Duration::from_millis(150)
    } else {
        Duration::from_millis(600)
    };
    println!(
        "bytes/device (SoA columns): {}\n",
        ParkLedger::bytes_per_device()
    );

    // bit-identity spot check before any timing: the two modes must
    // agree to the bit on the books they are about to be raced on
    {
        let mut e = build_ledger(1_000, LedgerMode::Eager);
        let mut l = build_ledger(1_000, LedgerMode::Lazy);
        for r in 1..=25 {
            run_round(&mut e, r, 4);
            run_round(&mut l, r, 4);
        }
        l.settle_all();
        let (te, tl) = (e.totals(), l.totals());
        assert_eq!(
            te.sleep_uah.to_bits(),
            tl.sleep_uah.to_bits(),
            "lazy ledger diverged from eager — benchmark void"
        );
        assert_eq!(te.charged_uah.to_bits(), tl.charged_uah.to_bits());
        println!("bit-identity spot check (n=1000, 25 rounds): ok\n");
    }

    let mut results: Vec<BenchResult> = Vec::new();
    let mut lazy_rps_gate = None;
    let mut speedup_1e5 = None;
    println!(
        "{:>10} {:>14} {:>14} {:>9}",
        "devices", "eager rds/s", "lazy rds/s", "speedup"
    );
    for &n in fleets {
        // the eager sweep at 10⁶ devices is exactly the wall the lazy
        // ledger removes; measuring it would spend the whole budget on
        // the baseline, so the largest fleet is lazy-only
        let eager_rps = if n <= 100_000 {
            let (rps, _) = measure(n, LedgerMode::Eager, budget);
            Some(rps)
        } else {
            None
        };
        let (lazy_rps, lazy_rounds) = measure(n, LedgerMode::Lazy, budget);
        assert!(lazy_rounds >= 2, "lazy mode failed to complete rounds at n={n}");
        if n == GATE_N {
            lazy_rps_gate = Some(lazy_rps);
        }
        let speedup = eager_rps.map(|e| lazy_rps / e);
        if n == 100_000 {
            speedup_1e5 = speedup;
        }
        println!(
            "{:>10} {:>14} {:>14} {:>9}",
            n,
            eager_rps.map_or("—".to_string(), |e| format!("{e:.1}")),
            format!("{lazy_rps:.1}"),
            speedup.map_or("—".to_string(), |s| format!("{s:.1}×")),
        );
        for (mode, rps) in [("eager", eager_rps), ("lazy", Some(lazy_rps))] {
            if let Some(rps) = rps {
                results.push(BenchResult {
                    name: format!("{mode}/n={n}"),
                    median: 1.0 / rps,
                    mean: 1.0 / rps,
                    std: 0.0,
                    iters_per_sample: 1,
                    samples: 1,
                });
            }
        }
    }
    if let Some(s) = speedup_1e5 {
        if s < 10.0 {
            println!("\nwarning: lazy speedup at 10^5 devices is {s:.1}× (< 10× target)");
        } else {
            println!("\nlazy speedup at 10^5 devices: {s:.1}× (target ≥ 10×)");
        }
    }

    // --- full-engine sweep: the same fleet sizes, but every round goes
    // through `Federation::run_round` over the columnar store — probe,
    // CSB-F selection, training the hydrated S(k), charging and lazy
    // billing are all inside the measured window
    println!("\nfull engine (columnar fleet store, lazy ledger):");
    println!(
        "{:>10} {:>11} {:>15} {:>8}",
        "devices", "build (s)", "engine rds/s", "rounds"
    );
    let mut engine_rps_1e6 = None;
    for &n in fleets {
        let b0 = Instant::now();
        let mut fed = build_engine(n);
        let build_s = b0.elapsed().as_secs_f64();
        // one unmeasured round warms the availability columns
        fed.run_round();
        let t0 = Instant::now();
        let mut rounds = 0usize;
        while t0.elapsed() < budget || rounds < 2 {
            fed.run_round();
            rounds += 1;
        }
        let rps = rounds as f64 / t0.elapsed().as_secs_f64();
        // settle outside the window, but report it — deferred windows
        // are amortized to the stats read, not free
        let s0 = Instant::now();
        fed.settle_fleet();
        println!(
            "{:>10} {:>11.2} {:>15.1} {:>8}   settle {:.1} ms",
            n,
            build_s,
            rps,
            rounds,
            s0.elapsed().as_secs_f64() * 1e3
        );
        if n == 1_000_000 {
            engine_rps_1e6 = Some(rps);
        }
        results.push(BenchResult {
            name: format!("engine-columnar/n={n}"),
            median: 1.0 / rps,
            mean: 1.0 / rps,
            std: 0.0,
            iters_per_sample: 1,
            samples: 1,
        });
    }

    // --- settle-throughput sweep: after SETTLE_ROUNDS lazy rounds the
    // fleet holds a pending window chain per parked device;
    // `par_settle(k)` replays them on k scoped workers. k=1 is the
    // serial baseline; any k must be bit-identical, so the win is pure
    // wall clock. A twin-fleet spot check pins that before the timing.
    const SETTLE_ROUNDS: usize = 12;
    {
        let mut a = build_ledger(1_000, LedgerMode::Lazy);
        let mut b = build_ledger(1_000, LedgerMode::Lazy);
        for r in 1..=SETTLE_ROUNDS {
            run_round(&mut a, r, 4);
            run_round(&mut b, r, 4);
        }
        a.settle_all();
        b.par_settle(8);
        for (x, y) in a.rows().iter().zip(b.rows()) {
            assert_eq!(
                x.sleep_uah.to_bits(),
                y.sleep_uah.to_bits(),
                "par_settle diverged from serial at device {} — benchmark void",
                x.device
            );
            assert_eq!(x.idle_uah.to_bits(), y.idle_uah.to_bits());
            assert_eq!(x.charged_uah.to_bits(), y.charged_uah.to_bits());
            assert_eq!(x.awake_equiv_uah.to_bits(), y.awake_equiv_uah.to_bits());
        }
    }
    let settle_n = *fleets.last().unwrap();
    let settle_m = (settle_n / 1000).clamp(4, 64);
    println!(
        "\nparallel settle (lazy ledger, n={settle_n}, {SETTLE_ROUNDS} pending rounds; \
         serial/parallel bit-identity: ok):"
    );
    println!("{:>9} {:>16} {:>9}", "workers", "settle dev/s", "speedup");
    let mut settle_serial_rps = None;
    let mut settle_rps_best: Option<f64> = None;
    for &w in &[1usize, 2, 4, 8] {
        let mut l = build_ledger(settle_n, LedgerMode::Lazy);
        for r in 1..=SETTLE_ROUNDS {
            run_round(&mut l, r, settle_m);
        }
        let t0 = Instant::now();
        l.par_settle(w);
        let dt = t0.elapsed().as_secs_f64();
        let rps = settle_n as f64 / dt;
        if w == 1 {
            settle_serial_rps = Some(rps);
        }
        settle_rps_best = Some(settle_rps_best.map_or(rps, |b: f64| b.max(rps)));
        println!(
            "{:>9} {:>16} {:>9}",
            w,
            format!("{rps:.0}"),
            settle_serial_rps.map_or("—".to_string(), |s| format!("{:.1}×", rps / s)),
        );
        results.push(BenchResult {
            name: format!("settle/n={settle_n}/w={w}"),
            median: dt,
            mean: dt,
            std: 0.0,
            iters_per_sample: 1,
            samples: 1,
        });
    }

    let mut extra: Vec<(&str, String)> = vec![
        ("measured", "true".to_string()),
        (
            "bytes_per_device",
            ParkLedger::bytes_per_device().to_string(),
        ),
    ];
    if let Some(rps) = lazy_rps_gate {
        extra.push(("lazy_rps_1e4", json_f64(rps)));
    }
    if let Some(s) = speedup_1e5 {
        extra.push(("speedup_1e5", json_f64(s)));
    }
    if let Some(rps) = engine_rps_1e6 {
        extra.push(("engine_rps_1e6", json_f64(rps)));
    }
    if settle_n == 1_000_000 {
        if let Some(rps) = settle_rps_best {
            extra.push(("settle_rps_1e6", json_f64(rps)));
        }
    }
    write_results_json("fleet_scaling", &results, &extra);

    // --- regression gate vs the committed baseline
    let Ok(path) = std::env::var("DEAL_BENCH_BASELINE") else {
        return;
    };
    let Ok(doc) = std::fs::read_to_string(&path) else {
        eprintln!("warning: baseline {path} unreadable — gate skipped");
        return;
    };
    if !doc.contains("\"measured\":true") {
        println!(
            "baseline {path} is an unmeasured placeholder — gate informational only"
        );
        return;
    }
    let (Some(base), Some(now)) = (json_number(&doc, "lazy_rps_1e4"), lazy_rps_gate)
    else {
        eprintln!("warning: baseline {path} lacks lazy_rps_1e4 — gate skipped");
        return;
    };
    let floor = base * (1.0 - REGRESSION_FRAC);
    if now < floor {
        eprintln!(
            "FAIL: lazy rounds/sec at n={GATE_N} regressed: {now:.1} < {floor:.1} \
             (baseline {base:.1}, tolerance {REGRESSION_FRAC})"
        );
        std::process::exit(1);
    }
    println!(
        "regression gate ok: {now:.1} rounds/sec at n={GATE_N} \
         (baseline {base:.1}, floor {floor:.1})"
    );
}
