//! Shared helpers for the figure-regeneration benches.
//!
//! Every bench prints (a) the paper's reported numbers for the figure it
//! regenerates and (b) our measured rows, in the same units, so the
//! *shape* comparison (who wins, by roughly what factor) is immediate.
//! `DEAL_BENCH_SCALE` (default 1.0) multiplies the dataset scales for
//! quicker smoke runs.

// Each bench target compiles this module separately and uses a
// different subset of the helpers — the unused remainder is expected.
#![allow(dead_code)]

use deal::coordinator::device::DeviceSim;
use deal::coordinator::fleet::{build_devices, FleetConfig};
use deal::coordinator::Scheme;
use deal::data::Dataset;
use deal::power::governor::Policy;

/// Global scale knob for quick runs.
pub fn bench_scale() -> f64 {
    std::env::var("DEAL_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0)
}

/// Per-dataset scale that keeps a full bench run in seconds while
/// preserving relative cardinalities (documented in EXPERIMENTS.md).
pub fn dataset_scale(ds: Dataset) -> f64 {
    let base = match ds {
        Dataset::Movielens => 0.50,
        Dataset::Jester => 0.05,
        Dataset::Mushrooms => 0.40,
        Dataset::Phishing => 0.30,
        Dataset::Covtype => 0.02,
        Dataset::Housing => 1.0,
        Dataset::Cadata => 0.20,
        Dataset::YearPredictionMSD => 0.01,
        Dataset::Cifar10 => 0.01,
        Dataset::Mnist => 0.01,
    };
    (base * bench_scale()).clamp(0.0005, 1.0)
}

/// Build one device carrying the full (scaled) dataset — the Fig. 3/6
/// single-phone (Honor) setting.
pub fn single_device(ds: Dataset, scheme: Scheme, step: Option<usize>, seed: u64) -> DeviceSim {
    let cfg = FleetConfig {
        n_devices: 1,
        dataset: ds,
        scale: dataset_scale(ds),
        scheme,
        policy: step.map(Policy::Fixed),
        seed,
        ..FleetConfig::default()
    };
    build_devices(&cfg).into_iter().next().unwrap()
}

/// Measure `rounds` rounds on a fresh device; returns (Σ compute_s,
/// Σ energy_uah, Σ swaps).
pub fn measure_rounds(
    mut dev: DeviceSim,
    scheme: Scheme,
    rounds: usize,
    arrivals: usize,
    theta: f64,
) -> (f64, f64, u64) {
    let mut t = 0.0;
    let mut e = 0.0;
    let mut s = 0;
    for _ in 0..rounds {
        let out = dev.run_round(scheme, arrivals, theta);
        t += out.compute_s;
        e += out.energy_uah;
        s += out.swaps;
    }
    (t, e, s)
}

/// Paper-style banner.
pub fn banner(fig: &str, claim: &str) {
    println!("\n================================================================");
    println!("{fig}");
    println!("paper: {claim}");
    println!("================================================================");
}
