//! Fig. 3 — training completion time of DEAL / NewFL / Original across
//! the four model×dataset panels, under different CPU frequencies on the
//! Honor profile.
//!
//! Paper shape: DEAL is 1–2 orders faster than NewFL and 2–4 orders
//! faster than Original; the gap widens with dataset size (phishing,
//! covtype, YearPredictionMSD).
//!
//!     cargo bench --bench fig3_training_time

mod common;

use common::{banner, dataset_scale, measure_rounds};
use deal::coordinator::fleet::{build_devices, FleetConfig};
use deal::coordinator::{ModelKind, Scheme};
use deal::data::Dataset;
use deal::power::governor::Policy;
use deal::power::profile::honor;
use deal::util::tables::{fmt_duration, Table};

const PANELS: [(&str, Option<ModelKind>, &[Dataset]); 4] = [
    ("(a) Personalized PageRank", None, &[Dataset::Movielens, Dataset::Jester]),
    ("(b) kNN-LSH", None, &[Dataset::Mushrooms, Dataset::Phishing]),
    (
        "(c) Multinomial Naive Bayes",
        Some(ModelKind::NaiveBayes),
        &[Dataset::Mushrooms, Dataset::Phishing, Dataset::Covtype],
    ),
    (
        "(d) Tikhonov Regularization",
        None,
        &[Dataset::Housing, Dataset::Cadata, Dataset::YearPredictionMSD],
    ),
];

fn device(ds: Dataset, model: Option<ModelKind>, scheme: Scheme, step: usize) -> deal::coordinator::DeviceSim {
    let cfg = FleetConfig {
        n_devices: 1,
        dataset: ds,
        scale: dataset_scale(ds),
        model,
        scheme,
        policy: Some(Policy::Fixed(step)),
        seed: 5,
        ..FleetConfig::default()
    };
    build_devices(&cfg).into_iter().next().unwrap()
}

fn main() {
    banner(
        "Fig. 3 — training completion time vs scheme vs CPU frequency (Honor)",
        "DEAL 1–2 orders faster than NewFL, 2–4 orders faster than Original",
    );
    let profile = honor();
    let steps = [0usize, profile.n_freq_steps() / 2, profile.n_freq_steps() - 1];
    let rounds = 5;
    let arrivals = 10;

    for (panel, model, datasets) in PANELS {
        let mut table = Table::new(
            &format!("Fig. 3{panel}"),
            &["dataset", "freq", "DEAL", "NewFL", "Original", "Orig/DEAL", "NewFL/DEAL"],
        );
        for &ds in datasets {
            for &step in &steps {
                let run = |scheme: Scheme, theta: f64| {
                    measure_rounds(device(ds, model, scheme, step), scheme, rounds, arrivals, theta).0
                };
                let deal_t = run(Scheme::Deal, 0.3);
                let newfl_t = run(Scheme::NewFl, 0.0);
                let orig_t = run(Scheme::Original, 0.0);
                table.row([
                    ds.name().to_string(),
                    format!("{:.2}GHz", profile.freqs_ghz[step]),
                    fmt_duration(deal_t),
                    fmt_duration(newfl_t),
                    fmt_duration(orig_t),
                    format!("{:.0}x", orig_t / deal_t.max(1e-12)),
                    format!("{:.1}x", newfl_t / deal_t.max(1e-12)),
                ]);
            }
        }
        print!("{}", table.render());
        println!();
    }
    println!("(dataset scales per EXPERIMENTS.md; shape target = ordering + order-of-magnitude gaps)");
}
