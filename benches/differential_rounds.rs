//! Differential round engine bench — deletion rate × rounds-mode sweep
//! (the PR 10 perf claim).
//!
//! `--rounds-mode differential` serves round probes and FORGET acks
//! from arranged per-device traces, so a round's evaluation cost tracks
//! the delta stream instead of O(model + holdout) per credited device.
//! This bench times `Federation::run_round` for recompute vs
//! differential across deletion-stream intensities on the PPR
//! (movielens) workload — the arranged-sparse path — after first
//! asserting the two modes agree to the bit on the bench config itself.
//!
//! Self-check: the deletion-heavy config must show ≥5× round
//! throughput. Asserted only when the full-size bench ran —
//! `DEAL_BENCH_FAST=1` shrinks the model below the regime the claim is
//! about, so fast runs report the ratio without gating on it.
//!
//!     cargo bench --bench differential_rounds

use deal::coordinator::fleet::{build, FleetConfig};
use deal::coordinator::{Federation, LedgerMode, RoundsMode, Scheme};
use deal::data::Dataset;
use deal::util::bench::{from_env, json_f64, write_results_json};

/// The tentpole's headline floor on the deletion-heavy config.
const SPEEDUP_TARGET: f64 = 5.0;
/// Allowed speedup shrink vs the committed baseline before the smoke fails.
const REGRESSION_FRAC: f64 = 0.25;

fn fast() -> bool {
    std::env::var("DEAL_BENCH_FAST").as_deref() == Ok("1")
}

/// Pull `"key": <number>` out of a JSON document (hand-rolled — the
/// crate is dependency-free, and the baseline schema is ours).
fn json_number(doc: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let at = doc.find(&pat)? + pat.len();
    let rest = doc[at..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || ".-+eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn cfg(rounds: RoundsMode, deletion_rate: f64, arrivals: usize, scale: f64) -> FleetConfig {
    FleetConfig {
        n_devices: 16,
        dataset: Dataset::Movielens,
        scale,
        scheme: Scheme::Deal,
        seed: 9,
        arrivals_per_round: arrivals,
        deletion_rate,
        deletion_slo: 3,
        ledger: LedgerMode::Lazy,
        rounds,
        ..FleetConfig::default()
    }
}

/// Build and run a few rounds so steady-state timing sees warmed
/// arenas, settled availability and (differential) arranged traces.
fn prewarmed(c: &FleetConfig) -> Federation {
    let mut fed = build(c);
    for _ in 0..3 {
        fed.run_round();
    }
    fed
}

fn main() {
    println!("== differential round engine (deletion rate × rounds-mode) ==");
    let b = from_env();
    let scale = if fast() { 0.05 } else { 0.3 };

    // bit-identity spot check before timing anything: on the bench
    // config itself, differential must equal recompute to the bit
    {
        let mut rec = build(&cfg(RoundsMode::Recompute, 2.0, 0, scale));
        let mut dif = build(&cfg(RoundsMode::Differential, 2.0, 0, scale));
        let a = rec.run(8);
        let d = dif.run(8);
        assert!(
            a == d,
            "differential diverged from recompute on the bench config — \
             timing a wrong computation is meaningless"
        );
        println!("bit-identity spot check ok (8 rounds, deletion-heavy)");
    }

    let mut results = Vec::new();
    let mut speedups: Vec<(&str, f64)> = Vec::new();
    // the sweep: idle probes (zero-delta cache reads), a mixed
    // train+delete stream, and the deletion-heavy headline (no
    // arrivals, the probe + forget-ack path is all evaluation)
    for (key, del, arrivals) in [
        ("differential_speedup_idle", 0.0, 0usize),
        ("differential_speedup_mixed", 0.5, 10),
        ("differential_speedup_deletion_heavy", 2.0, 0),
    ] {
        let mut rec = prewarmed(&cfg(RoundsMode::Recompute, del, arrivals, scale));
        let r_rec = b.run(
            &format!("round_recompute(del={del},arrivals={arrivals})"),
            || rec.run_round(),
        );
        let mut dif = prewarmed(&cfg(RoundsMode::Differential, del, arrivals, scale));
        let r_dif = b.run(
            &format!("round_differential(del={del},arrivals={arrivals})"),
            || dif.run_round(),
        );
        let speedup = r_rec.median / r_dif.median;
        println!("  {key}: {speedup:.2}x");
        results.push(r_rec);
        results.push(r_dif);
        speedups.push((key, speedup));
    }

    let headline = speedups
        .iter()
        .find(|(k, _)| *k == "differential_speedup_deletion_heavy")
        .map(|(_, s)| *s)
        .unwrap();
    let mut extra: Vec<(&str, String)> = vec![("measured", "true".to_string())];
    for (k, s) in &speedups {
        extra.push((k, json_f64(*s)));
    }
    write_results_json("differential_rounds", &results, &extra);

    if fast() {
        println!(
            "fast mode: ≥{SPEEDUP_TARGET}x self-check skipped \
             (shrunk model is below the claim's regime)"
        );
    } else {
        assert!(
            headline >= SPEEDUP_TARGET,
            "deletion-heavy round throughput: differential is only {headline:.2}x \
             recompute (target ≥{SPEEDUP_TARGET}x)"
        );
        println!("self-check ok: {headline:.2}x ≥ {SPEEDUP_TARGET}x on deletion-heavy rounds");
    }

    // --- regression gate vs the committed BENCH_differential.json
    // baseline (informational until the baseline carries "measured": true)
    let Ok(path) = std::env::var("DEAL_BENCH_BASELINE") else {
        return;
    };
    let Ok(doc) = std::fs::read_to_string(&path) else {
        eprintln!("warning: baseline {path} unreadable — gate skipped");
        return;
    };
    if !doc.contains("\"measured\":true") {
        println!("baseline {path} is an unmeasured placeholder — gate informational only");
        return;
    }
    let Some(base) = json_number(&doc, "differential_speedup_deletion_heavy") else {
        eprintln!(
            "warning: baseline {path} lacks differential_speedup_deletion_heavy — gate skipped"
        );
        return;
    };
    let floor = base * (1.0 - REGRESSION_FRAC);
    if headline < floor {
        eprintln!(
            "FAIL: deletion-heavy differential speedup regressed: {headline:.2}x < \
             {floor:.2}x (baseline {base:.2}x, tolerance {REGRESSION_FRAC})"
        );
        std::process::exit(1);
    }
    println!(
        "regression gate ok: {headline:.2}x deletion-heavy speedup \
         (baseline {base:.2}x, floor {floor:.2}x)"
    );
}
