//! Deletion-SLO sweep: deletion rate × forget degree θ × aggregation
//! policy on a DEAL federation with the targeted-unlearning pipeline
//! live (coordinator::unlearn).
//!
//! The paper's privacy story (Fig. 1, §III-D) deletes *specific users'
//! data* from live models; this sweep measures what that costs the
//! federation: how many rounds a GDPR request waits (p50/p99
//! rounds-to-forget), how often the forget guard vetoes a deletion, how
//! many SLO wake-overrides the engine fires past the bandit, and what
//! share of the fleet's energy the FORGET traffic burns. Deletion acks
//! are credited on the virtual clock (rounds are never stalled), so the
//! interesting motion is all in the SLO columns.
//!
//!     cargo bench --bench unlearn_slo
//!     DEAL_BENCH_SCALE=0.2 cargo bench --bench unlearn_slo   # quick
//!
//! Expected shape: higher deletion rates lengthen the queue (p99 grows,
//! wakeups rise); higher θ shrinks the absorbed pool so more requests
//! resolve as already-gone rotations; wait-all aggregation serves no
//! faster than majority (scheduling is selection-driven, not
//! aggregation-driven).

mod common;

use common::{banner, bench_scale};
use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::{Aggregation, Scheme};
use deal::data::Dataset;
use deal::util::tables::{fmt_uah, Table};

const DEVICES: usize = 12;

fn run_cell(rate: f64, theta: f64, agg: Aggregation, rounds: usize) -> deal::coordinator::FederationStats {
    let mut fed = fleet::build(&FleetConfig {
        n_devices: DEVICES,
        dataset: Dataset::Movielens,
        scale: (0.05 * bench_scale()).clamp(0.005, 1.0),
        scheme: Scheme::Deal,
        theta,
        m: 4,
        ttl_s: 2.0,
        seed: 2121,
        aggregation: Some(agg),
        deletion_rate: rate,
        deletion_slo: 3,
        ..FleetConfig::default()
    });
    fed.run(rounds)
}

fn main() {
    banner(
        "Deletion-SLO sweep — GDPR deletion rate × θ × aggregation (12-device DEAL fleet)",
        "DEAL deletes specific users' data from live models via decremental FORGET (Fig. 1, §III-D)",
    );
    let rounds = if bench_scale() >= 1.0 { 60 } else { 25 };
    let rates = [0.25f64, 1.0, 4.0];
    let thetas = [0.0f64, 0.3, 0.6];
    let aggs = [
        Aggregation::Majority,
        Aggregation::WaitAll,
        Aggregation::AsyncBuffered { staleness: 2 },
    ];
    let mut table = Table::new(
        &format!("{rounds} rounds per cell (same seed; SLO deadline = 3 rounds)"),
        &[
            "del/rnd", "θ", "aggregation", "served", "pending", "p50", "p99",
            "denials", "wakeups", "forget-E", "E share",
        ],
    );
    let mut total_served = 0u64;
    for &rate in &rates {
        for &theta in &thetas {
            for &agg in &aggs {
                let s = run_cell(rate, theta, agg, rounds);
                let u = &s.unlearn;
                total_served += u.served;
                let share = if s.total_energy_uah > 0.0 {
                    100.0 * u.forget_energy_uah / s.total_energy_uah
                } else {
                    0.0
                };
                table.row([
                    format!("{rate:.2}"),
                    format!("{theta:.1}"),
                    agg.name(),
                    format!("{}/{}", u.served, u.submitted),
                    u.pending.to_string(),
                    format!("{:.1}", u.rounds_to_forget_p50),
                    format!("{:.1}", u.rounds_to_forget_p99),
                    u.guard_denials.to_string(),
                    u.overdue_wakeups.to_string(),
                    fmt_uah(u.forget_energy_uah),
                    format!("{share:.2}%"),
                ]);
                // self-checking sweep: the pipeline must actually serve
                // under every policy, audits must pass, books balance
                assert!(u.submitted > 0, "stream produced nothing at rate {rate}");
                assert!(u.served > 0, "nothing served at rate {rate} θ={theta}");
                assert_eq!(
                    u.served + u.pending as u64,
                    u.submitted,
                    "SLO books out of balance"
                );
                assert_eq!(u.audit_failures, 0, "audit failures at rate {rate}");
            }
        }
    }
    print!("{}", table.render());
    println!(
        "\n(p50/p99 = rounds from GDPR submission to the FORGET ack; wakeups = devices \
         force-selected past the bandit because a request blew the 3-round SLO; the \
         energy share is the targeted-FORGET fraction of total fleet energy — deletion \
         acks ride the virtual clock and never extend a round's aggregation cut)"
    );
    assert!(total_served > 0);
}
