//! Fig. 5 — model accuracy of DEAL vs Original on the Tikhonov
//! regularization model across datasets.
//!
//! Paper shape: DEAL trails Original by ≤ 12% (housing worst at −12%,
//! phishing −9%, the rest ≈ −3%).
//!
//!     cargo bench --bench fig5_accuracy

mod common;

use common::{banner, dataset_scale};
use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::{ModelKind, Scheme};
use deal::data::Dataset;
use deal::util::tables::Table;

// the paper runs Tikhonov on its regression sets and reports phishing/
// mushrooms/covtype too (count features regressed on their class); we
// use the regression sets + classification sets via NB/kNN accuracy
const DATASETS: [Dataset; 6] = [
    Dataset::Housing,
    Dataset::Mushrooms,
    Dataset::Phishing,
    Dataset::Cadata,
    Dataset::YearPredictionMSD,
    Dataset::Covtype,
];

fn accuracy(ds: Dataset, scheme: Scheme) -> f64 {
    let model = match fleet::default_model(ds) {
        ModelKind::Ppr => Some(ModelKind::Ppr),
        m => Some(m),
    };
    let cfg = FleetConfig {
        n_devices: 8,
        dataset: ds,
        scale: dataset_scale(ds),
        model,
        scheme,
        theta: 0.3,
        seed: 55,
        ..FleetConfig::default()
    };
    let mut fed = fleet::build(&cfg);
    fed.run(15).final_accuracy
}

fn main() {
    banner(
        "Fig. 5 — accuracy, DEAL vs Original (θ=0.3)",
        "DEAL within 3% of Original on most sets; worst −12% (housing), −9% (phishing)",
    );
    let mut table = Table::new(
        "Fig. 5 — holdout accuracy after 15 rounds",
        &["dataset", "model", "DEAL", "Original", "Δ (pp)"],
    );
    for ds in DATASETS {
        let d = accuracy(ds, Scheme::Deal);
        let o = accuracy(ds, Scheme::Original);
        table.row([
            ds.name().to_string(),
            fleet::default_model(ds).name().to_string(),
            format!("{d:.3}"),
            format!("{o:.3}"),
            format!("{:+.1}", (d - o) * 100.0),
        ]);
    }
    print!("{}", table.render());
    println!("\n(shape target: DEAL within ~12pp of Original everywhere, usually ~3pp)");
}
