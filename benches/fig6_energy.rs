//! Fig. 6 — energy consumed during training per scheme and CPU frequency
//! (Honor profile), same panel grid as Fig. 3 — **plus the headline**:
//! the fleet power-state ledger behind the paper's 75.6–82.4% claim.
//! Conventional FL keeps the whole fleet idle-awake between training
//! bursts; DEAL parks unselected workers in deep sleep. The headline
//! table runs the default fleet under every `FleetMode` and reports the
//! per-state breakdown (train / idle-awake / sleep / wake / forget),
//! which must sum to the fleet total *exactly*, and the savings ratio
//! vs the all-awake baseline, which must land ≥ 50% (self-checked).
//!
//! Paper shape (panels): energy decreases with lower CPU frequency for
//! every scheme; DEAL saves e.g. 3687.1µAh vs Original on movielens,
//! ~300µAh on jester, ~110,000µAh on phishing (kNN), 17,908.1µAh on
//! covtype (MNB), 77,497.6µAh on YearPredictionMSD, only 6.7µAh on
//! housing (too small to matter).
//!
//!     cargo bench --bench fig6_energy

mod common;

use common::{banner, dataset_scale, measure_rounds};
use deal::coordinator::fleet::{self, build_devices, FleetConfig};
use deal::coordinator::{FederationStats, ModelKind, Scheme};
use deal::data::Dataset;
use deal::power::governor::Policy;
use deal::power::profile::honor;
use deal::power::{FleetMode, ALL_FLEET_MODES};
use deal::util::tables::{fmt_uah, Table};

const PANELS: [(&str, Option<ModelKind>, &[Dataset]); 4] = [
    ("(a) Personalized PageRank", None, &[Dataset::Movielens, Dataset::Jester]),
    ("(b) kNN-LSH", None, &[Dataset::Mushrooms, Dataset::Phishing]),
    (
        "(c) Multinomial Naive Bayes",
        Some(ModelKind::NaiveBayes),
        &[Dataset::Mushrooms, Dataset::Phishing, Dataset::Covtype],
    ),
    (
        "(d) Tikhonov Regularization",
        None,
        &[Dataset::Housing, Dataset::Cadata, Dataset::YearPredictionMSD],
    ),
];

fn energy(ds: Dataset, model: Option<ModelKind>, scheme: Scheme, step: usize) -> f64 {
    let cfg = FleetConfig {
        n_devices: 1,
        dataset: ds,
        scale: dataset_scale(ds),
        model,
        scheme,
        policy: Some(Policy::Fixed(step)),
        seed: 5,
        ..FleetConfig::default()
    };
    let dev = build_devices(&cfg).into_iter().next().unwrap();
    let theta = if scheme == Scheme::Deal { 0.3 } else { 0.0 };
    measure_rounds(dev, scheme, 5, 10, theta).1
}

fn main() {
    banner(
        "Fig. 6 — training energy vs scheme vs CPU frequency (Honor)",
        "energy falls with frequency; DEAL saves 1–4 orders vs Original by dataset size",
    );
    let profile = honor();
    let steps = [0usize, profile.n_freq_steps() / 2, profile.n_freq_steps() - 1];
    for (panel, model, datasets) in PANELS {
        let mut table = Table::new(
            &format!("Fig. 6{panel}"),
            &["dataset", "freq", "DEAL", "NewFL", "Original", "saved vs Orig"],
        );
        for &ds in datasets {
            for &step in &steps {
                let d = energy(ds, model, Scheme::Deal, step);
                let n = energy(ds, model, Scheme::NewFl, step);
                let o = energy(ds, model, Scheme::Original, step);
                table.row([
                    ds.name().to_string(),
                    format!("{:.2}GHz", profile.freqs_ghz[step]),
                    fmt_uah(d),
                    fmt_uah(n),
                    fmt_uah(o),
                    fmt_uah(o - d),
                ]);
            }
        }
        print!("{}", table.render());
        println!();
    }
    // fleet-level view: the DEAL-vs-NewFL gap is the *selection* effect —
    // NewFL trains every available device each round, DEAL trains m
    println!();
    let mut fleet_table = Table::new(
        "Fig. 6 (fleet view) — 16 devices, m=4, 10 rounds, movielens",
        &["scheme", "fleet energy", "vs DEAL"],
    );
    let fleet_energy = |scheme: Scheme| {
        let cfg = FleetConfig {
            n_devices: 16,
            dataset: Dataset::Movielens,
            scale: dataset_scale(Dataset::Movielens),
            scheme,
            m: 4,
            seed: 5,
            ..FleetConfig::default()
        };
        fleet::build(&cfg).run(10).total_energy_uah
    };
    let d = fleet_energy(Scheme::Deal);
    let n = fleet_energy(Scheme::NewFl);
    let o = fleet_energy(Scheme::Original);
    for (name, e) in [("DEAL", d), ("NewFL", n), ("Original", o)] {
        fleet_table.row([
            name.to_string(),
            fmt_uah(e),
            format!("{:.2}x", e / d),
        ]);
    }
    print!("{}", fleet_table.render());
    println!("\n(per-dataset scales shrink absolute µAh; shape = ordering + savings growth with dataset size)");

    // ------------------------------------------------------------------
    // Headline: the fleet power-state ledger. `deal run --mode allawake`
    // vs `--mode deal` on the default fleet — the whole-fleet footprint
    // by state and the savings ratio behind the 75.6–82.4% claim.
    // ------------------------------------------------------------------
    println!();
    let run_mode = |mode: FleetMode| -> FederationStats {
        let cfg = FleetConfig {
            seed: 5,
            mode: Some(mode),
            ..FleetConfig::default()
        };
        fleet::build(&cfg).run(10)
    };
    let mut headline = Table::new(
        "Fig. 6 (headline) — fleet ledger, default fleet (16 devices, m=4, 10 rounds, 60s period)",
        &[
            "mode", "train", "idle-awake", "sleep", "wake", "forget", "fleet total",
            "mean round s", "savings",
        ],
    );
    let mut by_mode = Vec::new();
    for mode in ALL_FLEET_MODES {
        let s = run_mode(mode);
        let b = s.fleet;
        // conservation: the printed breakdown must sum to the total
        // exactly — not approximately
        let sum = b.train_uah + b.idle_uah + b.sleep_uah + b.wake_uah + b.forget_uah;
        assert_eq!(
            sum.to_bits(),
            b.total_uah().to_bits(),
            "{}: breakdown does not sum to the fleet total",
            mode.name()
        );
        headline.row([
            mode.name().to_string(),
            fmt_uah(b.train_uah),
            fmt_uah(b.idle_uah),
            fmt_uah(b.sleep_uah),
            fmt_uah(b.wake_uah),
            fmt_uah(b.forget_uah),
            fmt_uah(b.total_uah()),
            format!("{:.3}", s.total_time_s / s.rounds as f64),
            format!("{:.1}%", 100.0 * s.savings_vs_allawake),
        ]);
        by_mode.push((mode, s));
    }
    print!("{}", headline.render());
    let deal_stats = &by_mode[0].1;
    let awake_stats = &by_mode[1].1;
    // measured headline: DEAL's fleet footprint vs the *actually run*
    // all-awake fleet (same seed), alongside the emulated baseline the
    // engine reports per-run
    let measured = 1.0 - deal_stats.fleet.total_uah() / awake_stats.fleet.total_uah();
    println!(
        "\nheadline: DEAL fleet {} vs all-awake fleet {} → {:.1}% savings measured \
         ({:.1}% vs emulated baseline; paper reports 75.6–82.4%)",
        fmt_uah(deal_stats.fleet.total_uah()),
        fmt_uah(awake_stats.fleet.total_uah()),
        100.0 * measured,
        100.0 * deal_stats.savings_vs_allawake,
    );
    assert!(
        measured >= 0.5,
        "measured fleet savings {measured:.3} below the paper's ballpark (≥ 50%)"
    );
    assert!(
        deal_stats.savings_vs_allawake >= 0.5,
        "emulated-baseline savings {:.3} below the paper's ballpark (≥ 50%)",
        deal_stats.savings_vs_allawake
    );
    assert_eq!(
        awake_stats.savings_vs_allawake, 0.0,
        "the all-awake fleet must be its own baseline"
    );
}
