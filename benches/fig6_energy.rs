//! Fig. 6 — energy consumed during training per scheme and CPU frequency
//! (Honor profile), same panel grid as Fig. 3.
//!
//! Paper shape: energy decreases with lower CPU frequency for every
//! scheme; DEAL saves e.g. 3687.1µAh vs Original on movielens, ~300µAh
//! on jester, ~110,000µAh on phishing (kNN), 17,908.1µAh on covtype
//! (MNB), 77,497.6µAh on YearPredictionMSD, only 6.7µAh on housing
//! (too small to matter).
//!
//!     cargo bench --bench fig6_energy

mod common;

use common::{banner, dataset_scale, measure_rounds};
use deal::coordinator::fleet::{build_devices, FleetConfig};
use deal::coordinator::{ModelKind, Scheme};
use deal::data::Dataset;
use deal::power::governor::Policy;
use deal::power::profile::honor;
use deal::util::tables::{fmt_uah, Table};

const PANELS: [(&str, Option<ModelKind>, &[Dataset]); 4] = [
    ("(a) Personalized PageRank", None, &[Dataset::Movielens, Dataset::Jester]),
    ("(b) kNN-LSH", None, &[Dataset::Mushrooms, Dataset::Phishing]),
    (
        "(c) Multinomial Naive Bayes",
        Some(ModelKind::NaiveBayes),
        &[Dataset::Mushrooms, Dataset::Phishing, Dataset::Covtype],
    ),
    (
        "(d) Tikhonov Regularization",
        None,
        &[Dataset::Housing, Dataset::Cadata, Dataset::YearPredictionMSD],
    ),
];

fn energy(ds: Dataset, model: Option<ModelKind>, scheme: Scheme, step: usize) -> f64 {
    let cfg = FleetConfig {
        n_devices: 1,
        dataset: ds,
        scale: dataset_scale(ds),
        model,
        scheme,
        policy: Some(Policy::Fixed(step)),
        seed: 5,
        ..FleetConfig::default()
    };
    let dev = build_devices(&cfg).into_iter().next().unwrap();
    let theta = if scheme == Scheme::Deal { 0.3 } else { 0.0 };
    measure_rounds(dev, scheme, 5, 10, theta).1
}

fn main() {
    banner(
        "Fig. 6 — training energy vs scheme vs CPU frequency (Honor)",
        "energy falls with frequency; DEAL saves 1–4 orders vs Original by dataset size",
    );
    let profile = honor();
    let steps = [0usize, profile.n_freq_steps() / 2, profile.n_freq_steps() - 1];
    for (panel, model, datasets) in PANELS {
        let mut table = Table::new(
            &format!("Fig. 6{panel}"),
            &["dataset", "freq", "DEAL", "NewFL", "Original", "saved vs Orig"],
        );
        for &ds in datasets {
            for &step in &steps {
                let d = energy(ds, model, Scheme::Deal, step);
                let n = energy(ds, model, Scheme::NewFl, step);
                let o = energy(ds, model, Scheme::Original, step);
                table.row([
                    ds.name().to_string(),
                    format!("{:.2}GHz", profile.freqs_ghz[step]),
                    fmt_uah(d),
                    fmt_uah(n),
                    fmt_uah(o),
                    fmt_uah(o - d),
                ]);
            }
        }
        print!("{}", table.render());
        println!();
    }
    // fleet-level view: the DEAL-vs-NewFL gap is the *selection* effect —
    // NewFL trains every available device each round, DEAL trains m
    println!();
    let mut fleet_table = Table::new(
        "Fig. 6 (fleet view) — 16 devices, m=4, 10 rounds, movielens",
        &["scheme", "fleet energy", "vs DEAL"],
    );
    let fleet_energy = |scheme: Scheme| {
        use deal::coordinator::fleet;
        let cfg = FleetConfig {
            n_devices: 16,
            dataset: Dataset::Movielens,
            scale: dataset_scale(Dataset::Movielens),
            scheme,
            m: 4,
            seed: 5,
            ..FleetConfig::default()
        };
        fleet::build(&cfg).run(10).total_energy_uah
    };
    let d = fleet_energy(Scheme::Deal);
    let n = fleet_energy(Scheme::NewFl);
    let o = fleet_energy(Scheme::Original);
    for (name, e) in [("DEAL", d), ("NewFL", n), ("Original", o)] {
        fleet_table.row([
            name.to_string(),
            fmt_uah(e),
            format!("{:.2}x", e / d),
        ]);
    }
    print!("{}", fleet_table.render());
    println!("\n(per-dataset scales shrink absolute µAh; shape = ordering + savings growth with dataset size)");
}
