//! Fig. 8 — the privacy proxy: proportion of the 10 newly-added data
//! objects in each round's training set, per scheme.
//!
//! Paper shape: NewFL is constant at 100% (trains only new data);
//! Original decays toward 0 as history accumulates; DEAL jitters high —
//! new data dominates but decremental deletions make it non-monotone.
//!
//!     cargo bench --bench fig8_privacy

mod common;

use common::banner;
use deal::coordinator::fleet::{build_devices, FleetConfig};
use deal::coordinator::Scheme;
use deal::data::Dataset;
use deal::util::tables::Table;

const ROUNDS: usize = 30;
const NEW_PER_ROUND: usize = 10;

/// Proportion of the round's training volume that is new data.
fn proportions(scheme: Scheme) -> Vec<f64> {
    let cfg = FleetConfig {
        n_devices: 1,
        dataset: Dataset::Cifar10,
        scale: 0.01,
        scheme,
        theta: 0.9,
        // devices start empty here: Fig. 8 watches fresh-data proportion
        // grow/decay from the first object
        prefill_frac: 0.0,
        seed: 808,
        ..FleetConfig::default()
    };
    let mut dev = build_devices(&cfg).into_iter().next().unwrap();
    let theta = if scheme == Scheme::Deal { 0.9 } else { 0.0 };
    (0..ROUNDS)
        .map(|_| {
            let out = dev.run_round(scheme, NEW_PER_ROUND, theta);
            // proportion of the *retained training window* that is this
            // round's new data (capped at 1: aggressive forgetting can
            // shrink the window below the arrival batch)
            let retained = out.retained_items.max(1);
            match scheme {
                // NewFL trains exactly the new objects
                Scheme::NewFl => 1.0,
                // Original retrains everything accumulated
                Scheme::Original => out.new_items as f64 / retained as f64,
                // DEAL trains new + forgets old: window stays bounded
                Scheme::Deal => {
                    out.new_items.min(retained) as f64 / retained as f64
                }
            }
        })
        .collect()
}

fn main() {
    banner(
        "Fig. 8 — proportion of 10 new objects in the per-round training set",
        "NewFL flat at 100%; Original decays; DEAL jitters high (forgets old data)",
    );
    let deal = proportions(Scheme::Deal);
    let orig = proportions(Scheme::Original);
    let newfl = proportions(Scheme::NewFl);
    let mut table = Table::new(
        "Fig. 8 — new-data proportion per round",
        &["round", "DEAL", "Original", "NewFL"],
    );
    for k in (0..ROUNDS).step_by(3) {
        table.row([
            format!("{}", k + 1),
            format!("{:.2}", deal[k]),
            format!("{:.2}", orig[k]),
            format!("{:.2}", newfl[k]),
        ]);
    }
    print!("{}", table.render());
    // shape assertions, reported not enforced
    let deal_final = deal[ROUNDS - 1];
    let orig_final = orig[ROUNDS - 1];
    println!(
        "\nfinal proportions: DEAL {:.2} > Original {:.2}; NewFL pinned at 1.00",
        deal_final, orig_final
    );
    println!("(DEAL stays high because θ-forgetting caps the retained window)");
}
