//! Async-aggregation sweep — the new scenario the transport-generic
//! engine opens: buffered-asynchronous rounds (`AsyncBuffered`, per the
//! async-FL literature) against the paper's majority/TTL cut, on
//! energy, convergence and round cadence.
//!
//! Setup: DEAL scheme (MAB selection active, so *delayed* rewards
//! actually exercise `Selector::observe_delayed`) with the TTL pinned
//! below the straggler tail: a pilot `WaitAll` run measures the mean
//! round time, then the TTL is set to 60% of it so slow phones
//! genuinely miss rounds. `Majority` discards nothing but cuts the
//! clock at the median reply; `async:<δ>` stops the clock at the TTL
//! and credits stragglers δ rounds later.
//!
//!     cargo bench --bench async_staleness

mod common;

use common::{banner, dataset_scale};
use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::{Aggregation, Scheme};
use deal::data::Dataset;
use deal::util::tables::{fmt_duration, fmt_uah, Table};

const N_DEVICES: usize = 24;
const ROUNDS: usize = 60;

fn cfg(ttl_s: f64, aggregation: Option<Aggregation>) -> FleetConfig {
    FleetConfig {
        n_devices: N_DEVICES,
        dataset: Dataset::Cadata,
        scale: dataset_scale(Dataset::Cadata),
        scheme: Scheme::Deal,
        m: 8,
        ttl_s,
        seed: 808,
        aggregation,
        ..FleetConfig::default()
    }
}

struct SweepRow {
    policy: String,
    virtual_time_s: f64,
    energy_uah: f64,
    converged: usize,
    median_conv_s: f64,
    final_acc: f64,
    pending: usize,
}

fn run(ttl_s: f64, aggregation: Aggregation) -> SweepRow {
    let mut fed = fleet::build(&cfg(ttl_s, Some(aggregation)));
    let stats = fed.run(ROUNDS);
    let mut conv = stats.convergence_times_s.clone();
    conv.sort_by(f64::total_cmp);
    SweepRow {
        policy: aggregation.name(),
        virtual_time_s: stats.total_time_s,
        energy_uah: stats.total_energy_uah,
        converged: stats.converged_devices,
        median_conv_s: conv.get(conv.len() / 2).copied().unwrap_or(f64::NAN),
        final_acc: stats.final_accuracy,
        pending: fed.pending_replies(),
    }
}

fn main() {
    banner(
        "Async sweep — AsyncBuffered staleness vs Majority (DEAL, Tikhonov/cadata)",
        "buffered-async rounds trade reward freshness for a TTL-bounded clock",
    );
    // pilot: WaitAll at a huge TTL measures the natural round time
    let pilot = fleet::build(&cfg(1e9, Some(Aggregation::WaitAll)))
        .run(10)
        .total_time_s
        / 10.0;
    let ttl = 0.6 * pilot;
    println!(
        "pilot mean round time {} → TTL pinned at {} (60%), {} devices, {} rounds\n",
        fmt_duration(pilot),
        fmt_duration(ttl),
        N_DEVICES,
        ROUNDS
    );

    let policies = [
        Aggregation::Majority,
        Aggregation::AsyncBuffered { staleness: 1 },
        Aggregation::AsyncBuffered { staleness: 2 },
        Aggregation::AsyncBuffered { staleness: 4 },
        Aggregation::AsyncBuffered { staleness: 8 },
    ];
    let mut table = Table::new(
        "aggregation sweep (same fleet, same seed)",
        &[
            "policy",
            "virtual time",
            "energy",
            "converged",
            "median conv",
            "final R²",
            "buffered at end",
        ],
    );
    for agg in policies {
        let r = run(ttl, agg);
        table.row([
            r.policy,
            fmt_duration(r.virtual_time_s),
            fmt_uah(r.energy_uah),
            format!("{}/{}", r.converged, N_DEVICES),
            if r.median_conv_s.is_nan() {
                "—".to_string()
            } else {
                fmt_duration(r.median_conv_s)
            },
            format!("{:.3}", r.final_acc),
            r.pending.to_string(),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\n(majority cuts the clock at the median reply; async:δ caps every round at \
         the TTL and credits stragglers δ rounds late — larger δ = staler rewards \
         reaching the bandit, more replies still buffered when the run ends)"
    );
}
