//! Ablation B — the forget degree θ and θ-LRU (§III-D design choices):
//! sweep θ ∈ {0, 0.1, …, 0.9} on PPR/I=1000 and report page swaps
//! (vs plain LRU), energy, and accuracy.
//!
//! Paper anchor: "given a θ = 30% configuration and PPR on I = 1000
//! items, DEAL uses θ-LRU to reduce up to 378 page swaps in memory
//! replacement during a single round."
//!
//!     cargo bench --bench ablation_theta

mod common;

use common::banner;
use deal::memsim::{PageCache, Replacement};
use deal::util::rng::{Rng, Zipf};
use deal::util::tables::Table;

const CAPACITY: usize = 1500; // frames: model state of PPR at I=1000
const ROUNDS: usize = 10;
const ACCESSES_PER_ROUND: usize = 4000;

/// PPR-like access trace at I=1000: row-major sweeps over touched items'
/// C/L rows plus Zipf-popular hot rows.
fn trace(seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(1000, 0.9);
    (0..ROUNDS)
        .map(|_| {
            (0..ACCESSES_PER_ROUND)
                .map(|_| {
                    let item = zipf.sample(&mut rng) as u64;
                    let offset = rng.below(4) as u64; // pages per row
                    item * 4 + offset
                })
                .collect()
        })
        .collect()
}

fn swaps_with(policy: Replacement, trace: &[Vec<u64>]) -> u64 {
    let mut cache = PageCache::new(CAPACITY, policy);
    for round in trace {
        cache.begin_round();
        for &p in round {
            cache.access(p);
        }
    }
    cache.stats().swaps
}

fn main() {
    banner(
        "Ablation B — θ sweep: θ-LRU swaps vs plain LRU (PPR, I=1000)",
        "θ=0.3 saves up to 378 swaps per round vs LRU",
    );
    let tr = trace(33);
    let lru_swaps = swaps_with(Replacement::Lru, &tr);
    let mut table = Table::new(
        "θ-LRU vs LRU page swaps (10 rounds, 4000 accesses/round)",
        &["θ", "swaps", "vs LRU", "saved/round"],
    );
    table.row([
        "LRU".into(),
        lru_swaps.to_string(),
        "1.00x".into(),
        "-".into(),
    ]);
    for theta10 in (1..=9).step_by(1) {
        let theta = theta10 as f64 / 10.0;
        let s = swaps_with(Replacement::ThetaLru { theta }, &tr);
        table.row([
            format!("{theta:.1}"),
            s.to_string(),
            format!("{:.2}x", s as f64 / lru_swaps.max(1) as f64),
            format!("{:.0}", (lru_swaps - s) as f64 / ROUNDS as f64),
        ]);
    }
    print!("{}", table.render());

    // accuracy side of the tradeoff: θ on the federated PPR run
    use common::dataset_scale;
    use deal::coordinator::fleet::{self, FleetConfig};
    use deal::coordinator::Scheme;
    use deal::data::Dataset;
    let mut acc_table = Table::new(
        "θ vs accuracy + energy (federated PPR on jester, 8 devices, 12 rounds)",
        &["θ", "accuracy", "energy (µAh)"],
    );
    for theta10 in [0, 1, 3, 5, 7, 9] {
        let theta = theta10 as f64 / 10.0;
        let cfg = FleetConfig {
            n_devices: 8,
            dataset: Dataset::Jester,
            scale: dataset_scale(Dataset::Jester),
            scheme: Scheme::Deal,
            theta,
            seed: 21,
            ..FleetConfig::default()
        };
        let mut fed = fleet::build(&cfg);
        let stats = fed.run(12);
        acc_table.row([
            format!("{theta:.1}"),
            format!("{:.3}", stats.final_accuracy),
            format!("{:.1}", stats.total_energy_uah),
        ]);
    }
    print!("{}", acc_table.render());
    println!("\n(paper anchor: ~378 swaps/round saved at θ=0.3; accuracy degrades gracefully with θ)");
}
