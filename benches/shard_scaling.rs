//! Shard-scaling sweep: devices × shards on the batched worker fabric.
//!
//! The ROADMAP's scale target — `n_devices ≫ 10³`, where DEAL's CSB-F
//! selector (§III-C) actually matters — needs two things from the
//! runtime: message cost per round that is O(workers), not O(devices)
//! (batched stepping in `ThreadedTransport`), and a fleet partitioned
//! across shard leaders (`ShardedTransport`) so no single fabric owns
//! every device. This sweep measures wall-clock build/run cost across
//! both axes on the MNIST-synth workload and re-checks the invariance
//! contract: for a fixed seed, merged stats are bit-identical for every
//! shard count.
//!
//!     cargo bench --bench shard_scaling
//!     DEAL_BENCH_SCALE=0.2 cargo bench --bench shard_scaling   # quick
//!
//! The acceptance-style headline row is 10⁴ devices × 8 shards over the
//! threaded fabric, 20 rounds — seconds, not minutes.

mod common;

use common::{banner, bench_scale};
use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::{FederationStats, Scheme, TransportKind};
use deal::data::Dataset;
use deal::util::tables::{fmt_duration, fmt_uah, Table};
use std::time::Instant;

const ROUNDS: usize = 20;

fn cfg(devices: usize, shards: usize, transport: TransportKind) -> FleetConfig {
    let m = (devices / 100).max(8);
    FleetConfig {
        n_devices: devices,
        dataset: Dataset::Mnist,
        scale: 0.05,
        scheme: Scheme::Deal,
        m,
        // deliberately feasible Eq. 4 fractions at any fleet size
        // (Σr = 0.25·m ≤ m), so the sweep never trips the fallback
        min_fraction: 0.25 * m as f64 / devices as f64,
        arrivals_per_round: 4,
        seed: 4242,
        transport,
        shards,
        ..FleetConfig::default()
    }
}

struct Row {
    devices: usize,
    shards: usize,
    topology: String,
    build_s: f64,
    run_s: f64,
    stats: FederationStats,
}

fn run(devices: usize, shards: usize, transport: TransportKind) -> Row {
    let t0 = Instant::now();
    let mut fed = fleet::build(&cfg(devices, shards, transport));
    let build_s = t0.elapsed().as_secs_f64();
    let topology = fed.transport().describe();
    let t1 = Instant::now();
    let stats = fed.run(ROUNDS);
    let run_s = t1.elapsed().as_secs_f64();
    Row { devices, shards, topology, build_s, run_s, stats }
}

fn main() {
    banner(
        "Shard scaling — devices × shards, batched threaded fabric (MNIST-synth, DEAL)",
        "process-level sharding + batched stepping keep 10⁴-device rounds in milliseconds",
    );
    // DEAL_BENCH_SCALE < 1 trims the fleet axis for smoke runs
    let fleets: Vec<usize> = if bench_scale() >= 1.0 {
        vec![256, 2048, 10_000]
    } else {
        vec![128, 512]
    };
    let shard_axis = [1usize, 2, 8];

    let mut table = Table::new(
        &format!("{ROUNDS} rounds per cell (same seed per fleet size)"),
        &[
            "devices", "shards", "topology", "build", "run", "rounds/s", "energy",
            "invariant",
        ],
    );
    let mut diverged = false;
    for &devices in &fleets {
        let mut baseline: Option<FederationStats> = None;
        for &shards in &shard_axis {
            let row = run(devices, shards, TransportKind::Threaded);
            let invariant = match &baseline {
                None => {
                    baseline = Some(row.stats.clone());
                    "baseline".to_string()
                }
                Some(b) => {
                    let same = b.total_energy_uah.to_bits()
                        == row.stats.total_energy_uah.to_bits()
                        && b.total_time_s.to_bits() == row.stats.total_time_s.to_bits()
                        && b.final_accuracy.to_bits()
                            == row.stats.final_accuracy.to_bits();
                    if !same {
                        diverged = true;
                    }
                    if same { "✓ bit-identical".to_string() } else { "✗ DIVERGED".to_string() }
                }
            };
            table.row([
                row.devices.to_string(),
                row.shards.to_string(),
                row.topology,
                fmt_duration(row.build_s),
                fmt_duration(row.run_s),
                format!("{:.1}", ROUNDS as f64 / row.run_s.max(1e-9)),
                fmt_uah(row.stats.total_energy_uah),
                invariant,
            ]);
        }
        // sync single-shard reference for the dispatch-overhead column
        let sync = run(devices, 1, TransportKind::Sync);
        table.row([
            sync.devices.to_string(),
            "1".to_string(),
            sync.topology,
            fmt_duration(sync.build_s),
            fmt_duration(sync.run_s),
            format!("{:.1}", ROUNDS as f64 / sync.run_s.max(1e-9)),
            fmt_uah(sync.stats.total_energy_uah),
            match &baseline {
                Some(b)
                    if b.total_energy_uah.to_bits()
                        == sync.stats.total_energy_uah.to_bits() =>
                {
                    "✓ bit-identical".to_string()
                }
                _ => {
                    diverged = true;
                    "✗ DIVERGED".to_string()
                }
            },
        ]);
    }
    print!("{}", table.render());
    println!(
        "\n(invariant column: merged FederationStats vs the shards=1 threaded baseline \
         at the same seed — the shard/transport/batch axes may never change a bit; \
         `rust/tests/transport_equivalence.rs` enforces the same contract in CI)"
    );
    // self-checking sweep: a diverged cell is a correctness regression,
    // not a formatting detail — fail the process so scripts notice
    assert!(!diverged, "shard/batch invariance violated — see table above");
}
