//! Fig. 4 — CDF of per-device convergence time, DEAL vs Original, on the
//! PPR model (movielens + jester), default (interactive) governor,
//! hundreds of simulated devices.
//!
//! Paper shape: DEAL's CDF sits orders of magnitude left of Original;
//! ≈92% (movielens) / 85% (jester) of devices converge faster under
//! DEAL; medians 158ms vs 94,988ms (movielens), 1ms vs 6,598ms (jester).
//!
//!     cargo bench --bench fig4_convergence_cdf

mod common;

use common::{banner, dataset_scale};
use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::Scheme;
use deal::data::Dataset;
use deal::power::governor::Policy;
use deal::util::stats::Cdf;
use deal::util::tables::{fmt_duration, Table};

const N_DEVICES: usize = 200;
const ROUNDS: usize = 60;

fn convergence_times(ds: Dataset, scheme: Scheme) -> Vec<f64> {
    let cfg = FleetConfig {
        n_devices: N_DEVICES,
        dataset: ds,
        scale: dataset_scale(ds),
        scheme,
        policy: Some(Policy::Interactive), // the paper's default governor
        m: N_DEVICES / 4,
        seed: 404,
        ..FleetConfig::default()
    };
    let mut fed = fleet::build(&cfg);
    let stats = fed.run(ROUNDS);
    // devices that never converged are charged their full busy time
    // via the TTL horizon (right-censored at the experiment end)
    let mut times = stats.convergence_times_s;
    let horizon = fed.clock_s.max(1.0);
    while times.len() < N_DEVICES {
        times.push(horizon);
    }
    times
}

fn main() {
    banner(
        "Fig. 4 — CDF of convergence time (PPR, interactive governor, 200 devices)",
        "DEAL medians orders of magnitude below Original; 85–92% of devices faster",
    );
    for ds in [Dataset::Movielens, Dataset::Jester] {
        let deal_times = convergence_times(ds, Scheme::Deal);
        let orig_times = convergence_times(ds, Scheme::Original);
        let deal_cdf = Cdf::new(deal_times.clone());
        let orig_cdf = Cdf::new(orig_times.clone());

        let mut table = Table::new(
            &format!("Fig. 4 ({}) — convergence-time CDF", ds.name()),
            &["percentile", "DEAL", "Original"],
        );
        for q in [10.0, 25.0, 50.0, 75.0, 90.0, 99.0] {
            table.row([
                format!("p{q:.0}"),
                fmt_duration(deal_cdf.quantile(q)),
                fmt_duration(orig_cdf.quantile(q)),
            ]);
        }
        print!("{}", table.render());
        let faster = deal_times
            .iter()
            .zip(&orig_times)
            .filter(|(d, o)| d < o)
            .count() as f64
            / N_DEVICES as f64;
        println!(
            "devices faster under DEAL: {:.0}%   median DEAL {} vs Original {} ({:.0}x)\n",
            faster * 100.0,
            fmt_duration(deal_cdf.median()),
            fmt_duration(orig_cdf.median()),
            orig_cdf.median() / deal_cdf.median().max(1e-9),
        );
    }
    println!("(paper: 92%/85% faster, medians 158ms vs 94,988ms and 1ms vs 6,598ms)");
}
