//! Federated Personalized PageRank: DEAL vs Original vs NewFL on the
//! MovieLens-shaped workload (the paper's headline scenario, Figs. 3a/6a).
//!
//!     cargo run --release --example federated_ppr
//!
//! Runs the same fleet/seed under all three schemes and prints the
//! training-time / energy / accuracy comparison.

use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::scheme::ALL_SCHEMES;
use deal::data::Dataset;
use deal::util::tables::{fmt_speedup, fmt_uah, Table};

fn main() {
    let rounds = 12;
    let mut table = Table::new(
        "Federated PPR on movielens (12 devices, 12 rounds)",
        &["scheme", "virtual time", "energy", "final accuracy", "time vs DEAL"],
    );
    let mut results = Vec::new();
    for scheme in ALL_SCHEMES {
        let cfg = FleetConfig {
            n_devices: 12,
            dataset: Dataset::Movielens,
            scale: 0.05,
            scheme,
            theta: 0.3,
            m: 4,
            seed: 7,
            ..FleetConfig::default()
        };
        let mut fed = fleet::build(&cfg);
        let stats = fed.run(rounds);
        results.push((scheme, stats));
    }
    let deal_time = results[0].1.total_time_s;
    for (scheme, s) in &results {
        table.row([
            scheme.name().to_string(),
            format!("{:.3}s", s.total_time_s),
            fmt_uah(s.total_energy_uah),
            format!("{:.3}", s.final_accuracy),
            fmt_speedup(s.total_time_s / deal_time),
        ]);
    }
    print!("{}", table.render());

    let orig = &results[1].1;
    let deal = &results[0].1;
    println!(
        "\nDEAL saves {:.1}% energy vs Original and finishes {} faster.",
        100.0 * (1.0 - deal.total_energy_uah / orig.total_energy_uah),
        fmt_speedup(orig.total_time_s / deal.total_time_s),
    );
}
