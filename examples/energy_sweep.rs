//! DVFS frequency sweep: energy and completion time for one local
//! training round at every ladder step of the Honor profile — the device-
//! level view behind Figs. 3/6 ("under different CPU frequencies").
//!
//!     cargo run --release --example energy_sweep

use deal::coordinator::device::DeviceSim;
use deal::coordinator::fleet::{build_devices, FleetConfig};
use deal::coordinator::Scheme;
use deal::data::Dataset;
use deal::memsim::Replacement;
use deal::power::governor::Policy;
use deal::power::profile::honor;
use deal::util::tables::{fmt_uah, Table};

fn device_at(step: usize, scheme: Scheme, seed: u64) -> DeviceSim {
    let cfg = FleetConfig {
        n_devices: 1,
        dataset: Dataset::YearPredictionMSD,
        scale: 0.02,
        scheme,
        policy: Some(Policy::Fixed(step)),
        seed,
        ..FleetConfig::default()
    };
    build_devices(&cfg).into_iter().next().unwrap()
}

fn main() {
    let profile = honor();
    println!(
        "Honor profile: {} cores, ladder {:?} GHz\n",
        profile.cores,
        profile
            .freqs_ghz
            .iter()
            .map(|f| (f * 100.0).round() / 100.0)
            .collect::<Vec<_>>()
    );

    let mut table = Table::new(
        "One Tikhonov training round on YearPredictionMSD (scale 2%), per CPU frequency",
        &["freq (GHz)", "DEAL time", "DEAL energy", "Original time", "Original energy"],
    );
    for step in 0..profile.n_freq_steps() {
        let mut deal_dev = device_at(step, Scheme::Deal, 3);
        let mut orig_dev = device_at(step, Scheme::Original, 3);
        // warm both up with the same history, then measure one round
        for _ in 0..3 {
            deal_dev.run_round(Scheme::Deal, 10, 0.3);
            orig_dev.run_round(Scheme::Original, 10, 0.0);
        }
        let d = deal_dev.run_round(Scheme::Deal, 10, 0.3);
        let o = orig_dev.run_round(Scheme::Original, 10, 0.0);
        table.row([
            format!("{:.2}", profile.freqs_ghz[step]),
            format!("{:.4}s", d.time_s),
            fmt_uah(d.energy_uah),
            format!("{:.4}s", o.time_s),
            fmt_uah(o.energy_uah),
        ]);
    }
    print!("{}", table.render());
    println!("\n(Original retrains everything each round; DEAL updates deltas and forgets θ=30%.)");
}
