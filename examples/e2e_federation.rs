//! End-to-end driver: proves all three layers compose on a real workload.
//!
//!     make artifacts && cargo run --release --example e2e_federation
//!
//! 1. **L1/L2 ⇄ L3 cross-validation** — the AOT `tikhonov_fit` /
//!    `tikhonov_step` artifacts (Pallas kernel + JAX graph, compiled via
//!    PJRT) are executed from rust on a 256×32 regression batch and
//!    checked against the native rust engine (QR rank-one path) to 1e-3.
//! 2. **Federated run** — a 24-device fleet (threaded PUB/SUB topology)
//!    trains Tikhonov under DEAL for 300 rounds with MAB selection;
//!    the same fleet/seed is replayed under Original and NewFL.
//!    Reports the convergence curve (accuracy every 25 rounds), total
//!    virtual time and energy — the paper's headline quantities.
//! 3. **Sharded multi-federation runtime** — replays a fleet across
//!    shard leaders to spot-check the bit-identical merge contract,
//!    then drives a 2000-device MNIST-synth fleet over 4 shard leaders
//!    of batched threaded workers (the ROADMAP scale path).
//! 4. **Heterogeneity-aware selection** — a 20-device fleet mixing all
//!    five Table I profiles is driven with CSB-F and with the
//!    telemetry-fed LinUCB selector at equal m; per-profile selection
//!    shares show LinUCB shifting toward high-battery / high-ladder /
//!    high-GFLOPS devices as the context model learns.
//!
//! Recorded in EXPERIMENTS.md §E2E.

use deal::bandit::SelectorKind;
use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::{ModelKind, Scheme, TransportKind};
use deal::data::synth;
use deal::learn::tikhonov::{Observation, Tikhonov};
use deal::runtime::{Engine, Registry, Tensor};
use deal::util::rng::Rng;
use deal::util::tables::{fmt_speedup, fmt_uah, Table};

fn main() {
    let t0 = std::time::Instant::now();
    cross_validate_artifacts();
    let results: Vec<(Scheme, RunResult)> = [Scheme::Deal, Scheme::Original, Scheme::NewFl]
        .into_iter()
        .map(|s| (s, federated_run(s)))
        .collect();
    report(&results);
    sharded_scale_demo();
    heterogeneous_selection_demo();
    println!("\n(e2e wall time: {:.1}s)", t0.elapsed().as_secs_f64());
}

/// Step 1: PJRT artifacts vs native rust engine on identical data.
fn cross_validate_artifacts() {
    println!("== step 1: L1/L2 artifacts (PJRT) vs L3 native engine ==");
    let reg = match Registry::load(Registry::default_dir()) {
        Ok(r) => r,
        Err(e) => {
            println!("  !! artifacts unavailable ({e}); run `make artifacts`. Skipping.");
            return;
        }
    };
    let mut engine = match Engine::new(reg) {
        Ok(e) => e,
        Err(e) => {
            println!("  !! PJRT engine unavailable ({e}). Skipping.");
            return;
        }
    };
    // batch at the canonical artifact shape: 256×32
    let mut rng = Rng::new(99);
    let (s, d) = (256usize, 32usize);
    let w: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut m32 = Vec::with_capacity(s * d);
    let mut r32 = Vec::with_capacity(s);
    let mut obs = Vec::with_capacity(s);
    for _ in 0..s {
        let row: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let target: f64 =
            row.iter().zip(&w).map(|(a, b)| a * b).sum::<f64>() + rng.normal_ms(0.0, 0.05);
        m32.extend(row.iter().map(|&x| x as f32));
        r32.push(target as f32);
        obs.push(Observation { m: row, r: target });
    }
    let lam = 1.0f32;
    // PJRT: (G, z, h) = tikhonov_fit(M, r, λ)
    let out = engine
        .call(
            "tikhonov_fit",
            &[Tensor::matrix(s, d, m32), Tensor::vec(r32), Tensor::scalar(lam)],
        )
        .expect("tikhonov_fit artifact");
    let h_pjrt = &out[2].data;
    // native: QR rank-one engine
    let native = Tikhonov::fit(d, lam as f64, &obs);
    let max_err = h_pjrt
        .iter()
        .zip(native.weights())
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0, f64::max);
    assert!(max_err < 1e-3, "artifact/native divergence {max_err}");
    println!(
        "  tikhonov_fit({}×{}) PJRT vs native max |Δh| = {:.2e}  ✓",
        s, d, max_err
    );

    // decremental step through the artifact: forget row 0
    let g = &out[0];
    let z = &out[1];
    let m0: Vec<f32> = obs[0].m.iter().map(|&x| x as f32).collect();
    let step = engine
        .call(
            "tikhonov_step",
            &[
                g.clone(),
                z.clone(),
                Tensor::vec(m0),
                Tensor::scalar(obs[0].r as f32),
                Tensor::scalar(-1.0),
            ],
        )
        .expect("tikhonov_step artifact");
    let refit = Tikhonov::fit(d, lam as f64, &obs[1..]);
    let max_err2 = step[2]
        .data
        .iter()
        .zip(refit.weights())
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0, f64::max);
    assert!(max_err2 < 1e-3, "FORGET divergence {max_err2}");
    println!("  tikhonov_step FORGET vs refit-without-row max |Δh| = {max_err2:.2e}  ✓");
}

struct RunResult {
    virtual_time_s: f64,
    /// Σ per-device training-compute seconds (comm excluded) — the
    /// paper's "training completion time" axis.
    compute_s: f64,
    energy_uah: f64,
    accuracy_curve: Vec<(usize, f64)>,
    final_accuracy: f64,
}

/// Step 2: 300 federated rounds over the threaded PUB/SUB transport —
/// the unified [`deal::coordinator::Federation`] engine carries the
/// round semantics (selection, majority/TTL cut, rewards, convergence);
/// only the worker fabric is parallel.
fn federated_run(scheme: Scheme) -> RunResult {
    let rounds = 300usize;
    let cfg = FleetConfig {
        n_devices: 24,
        dataset: synth::Dataset::Cadata,
        scale: 0.15,
        model: Some(ModelKind::Tikhonov),
        scheme,
        theta: 0.3,
        m: 6,
        arrivals_per_round: 4,
        seed: 2026,
        transport: TransportKind::Threaded,
        ..FleetConfig::default()
    };
    let mut fed = fleet::build(&cfg);
    let mut curve = Vec::new();
    let mut last_acc = 0.0;
    for round in 1..=rounds {
        let rec = fed.run_round();
        if rec.mean_accuracy > 0.0 {
            last_acc = rec.mean_accuracy;
        }
        if round % 25 == 0 {
            curve.push((round, last_acc));
        }
    }
    let stats = fed.stats();
    RunResult {
        virtual_time_s: stats.total_time_s,
        compute_s: fed.device_busy_s().iter().sum(),
        energy_uah: stats.total_energy_uah,
        accuracy_curve: curve,
        final_accuracy: last_acc,
    }
}

/// Step 3: the sharded multi-federation runtime — merge invariance at
/// small scale, then a large batched fleet at ROADMAP scale.
fn sharded_scale_demo() {
    println!("\n== step 3: sharded multi-federation runtime ==");
    // invariance spot-check: same fleet/seed, 1 vs 3 shard leaders
    let small = |shards: usize| FleetConfig {
        n_devices: 24,
        dataset: synth::Dataset::Cadata,
        scale: 0.1,
        model: Some(ModelKind::Tikhonov),
        scheme: Scheme::Deal,
        m: 6,
        seed: 2026,
        transport: TransportKind::Threaded,
        shards,
        ..FleetConfig::default()
    };
    let flat = fleet::build(&small(1)).run(40);
    let sharded = fleet::build(&small(3)).run(40);
    assert_eq!(
        flat.total_energy_uah.to_bits(),
        sharded.total_energy_uah.to_bits(),
        "sharded merge must be bit-identical to the flat path"
    );
    println!(
        "  24-device replay, shards 1 vs 3: energy {} both — bit-identical  ✓",
        fmt_uah(flat.total_energy_uah)
    );

    // scale: 2000 devices, 4 shard leaders of batched threaded workers
    let t0 = std::time::Instant::now();
    let cfg = FleetConfig {
        n_devices: 2000,
        dataset: synth::Dataset::Mnist,
        scale: 0.05,
        scheme: Scheme::Deal,
        m: 32,
        // feasible Eq. 4 fractions at fleet scale: Σr = 0.25·m ≤ m
        min_fraction: 0.25 * 32.0 / 2000.0,
        arrivals_per_round: 4,
        seed: 2026,
        transport: TransportKind::Threaded,
        shards: 4,
        ..FleetConfig::default()
    };
    let mut fed = fleet::build(&cfg);
    let topology = fed.transport().describe();
    let stats = fed.run(20);
    println!(
        "  2000-device MNIST-synth fleet over {topology}: 20 rounds in {:.2}s wall, \
         virtual time {:.2}s, energy {}",
        t0.elapsed().as_secs_f64(),
        stats.total_time_s,
        fmt_uah(stats.total_energy_uah)
    );
    for s in fed.shard_summaries() {
        println!(
            "    shard {}: devices {:>4}..{:<4}  replies {:>5}  energy {}",
            s.shard,
            s.start,
            s.end,
            s.replies,
            fmt_uah(s.energy_uah)
        );
    }
}

/// Step 4: LinUCB vs CSB-F on a profile-mixed fleet — where do the
/// selections land, and how does that shift as the context model learns?
fn heterogeneous_selection_demo() {
    println!("\n== step 4: heterogeneity-aware selection (telemetry → LinUCB) ==");
    let mk = |selector: SelectorKind| FleetConfig {
        n_devices: 20, // 4 of each Table I profile — heterogeneous fleet
        dataset: synth::Dataset::Cadata,
        scale: 0.1,
        model: Some(ModelKind::Tikhonov),
        scheme: Scheme::Deal,
        m: 5,
        arrivals_per_round: 4,
        ttl_s: 2.0,
        seed: 2026,
        selector,
        ..FleetConfig::default()
    };
    let profiles = ["Honor", "Lenovo", "ZTE", "Mi", "Nexus"];
    for selector in [SelectorKind::Csbf, SelectorKind::LinUcb] {
        let mut fed = fleet::build(&mk(selector));
        // early window: the first 20 rounds (exploration)
        for _ in 0..20 {
            fed.run_round();
        }
        let early: Vec<u64> = fed.selection_counts().to_vec();
        // late window: 80 more rounds (exploitation of learned context)
        for _ in 0..80 {
            fed.run_round();
        }
        let share = |counts: &[u64], name: &str| -> f64 {
            let total: u64 = counts.iter().sum::<u64>().max(1);
            let hits: u64 = (0..fed.n_devices())
                .filter(|&i| fed.transport().profile(i).name == name)
                .map(|i| counts[i])
                .sum();
            100.0 * hits as f64 / total as f64
        };
        let late: Vec<u64> = fed
            .selection_counts()
            .iter()
            .zip(&early)
            .map(|(t, e)| t - e)
            .collect();
        let fmt = |counts: &[u64]| -> String {
            profiles
                .iter()
                .map(|p| format!("{p} {:4.1}%", share(counts, p)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("  {:<7} rounds 1-20 : {}", selector.name(), fmt(&early));
        println!("  {:<7} rounds 21-100: {}", selector.name(), fmt(&late));
        // telemetry the selector acted on: mean battery of the most- vs
        // least-selected device (LinUCB should be protecting batteries)
        let counts = fed.selection_counts();
        let most = (0..fed.n_devices()).max_by_key(|&i| counts[i]).unwrap();
        let least = (0..fed.n_devices()).min_by_key(|&i| counts[i]).unwrap();
        println!(
            "          most-selected {} ({}, battery {:.0}%, {:.1} GFLOPS) · \
             least-selected {} ({}, battery {:.0}%, {:.1} GFLOPS)",
            most,
            fed.transport().profile(most).name,
            100.0 * fed.device_snapshot(most).battery_frac,
            fed.device_snapshot(most).peak_gflops,
            least,
            fed.transport().profile(least).name,
            100.0 * fed.device_snapshot(least).battery_frac,
            fed.device_snapshot(least).peak_gflops,
        );
    }
    println!(
        "  (LinUCB's late-window share should lean toward the high-capacity \
         Honor/Nexus profiles; CSB-F spreads by arm statistics alone)"
    );
}

fn report(results: &[(Scheme, RunResult)]) {
    println!("\n== step 2: 24-device federation, Tikhonov on cadata, 300 rounds ==");
    println!("accuracy (R²) every 25 rounds:");
    for (scheme, r) in results {
        let pts: Vec<String> = r
            .accuracy_curve
            .iter()
            .map(|(k, a)| format!("{k}:{a:.2}"))
            .collect();
        println!("  {:<9} {}", scheme.name(), pts.join("  "));
    }
    let mut table = Table::new(
        "e2e summary",
        &["scheme", "virtual time", "train compute", "energy", "final R²", "compute vs DEAL", "energy vs DEAL"],
    );
    let deal = &results[0].1;
    for (scheme, r) in results {
        table.row([
            scheme.name().to_string(),
            format!("{:.2}s", r.virtual_time_s),
            format!("{:.4}s", r.compute_s),
            fmt_uah(r.energy_uah),
            format!("{:.3}", r.final_accuracy),
            fmt_speedup(r.compute_s / deal.compute_s),
            format!("{:.2}x", r.energy_uah / deal.energy_uah),
        ]);
    }
    print!("{}", table.render());
    let orig = &results[1].1;
    println!(
        "\nheadline: DEAL uses {:.1}% less energy than Original, trains {} faster, \
         final accuracy within {:.1}%.",
        100.0 * (1.0 - deal.energy_uah / orig.energy_uah),
        fmt_speedup(orig.compute_s / deal.compute_s),
        100.0 * (orig.final_accuracy - deal.final_accuracy).abs(),
    );
}
