//! The paper's Fig. 1 privacy story, end to end:
//!
//! 1. Build a PPR model over a Retailrocket-style event log.
//! 2. User A invokes GDPR deletion; the *raw events* are removed — but a
//!    stale similarity model still leaks A's history (similar users B/C,
//!    matrix diffing).
//! 3. DEAL's remedy: FORGET the user from the model itself (Alg. 1),
//!    after which the leak is gone.
//!
//!     cargo run --release --example gdpr_forget

use deal::data::events::generate_events;
use deal::learn::recovery::{recover_deleted_items, recover_deleted_items_exact};
use deal::learn::{DecrementalModel, NullMiddleware, Ppr};

fn main() {
    // Retailrocket-shaped log: cohorts of users with shared tastes
    let log = generate_events(2026, 80, 400, 4, 50);
    let histories = log.user_histories();
    let user_a = 0usize;

    println!("== step 1: the service trains a PPR similarity model ==");
    let model = Ppr::fit(log.items, 10, &histories);
    println!(
        "  {} users, {} items, user A has {} interactions",
        log.users,
        log.items,
        histories[user_a].len()
    );

    // find A's most similar users (the paper's B and C)
    let mut sims: Vec<(usize, f64)> = (0..log.users)
        .filter(|&u| u != user_a)
        .map(|u| (u, log.user_jaccard(&histories[user_a], &histories[u])))
        .collect();
    sims.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!(
        "  most similar users to A: B=user{} ({:.2}), C=user{} ({:.2})",
        sims[0].0, sims[0].1, sims[1].0, sims[1].1
    );

    println!("\n== step 2: user A deletes their data (GDPR) — raw events only ==");
    let stale_sim = model.dense_similarity();
    let stale_counts = model.counts().to_vec();
    let mut after = model.clone();
    let mut mw = NullMiddleware;
    after.forget(&histories[user_a], &mut mw);

    // the attacker holds the stale model and observes the fresh one
    let candidates = recover_deleted_items(&stale_sim, &after.dense_similarity(), 1e-7);
    let exact = recover_deleted_items_exact(&stale_counts, after.counts());
    let hit = exact.iter().filter(|i| histories[user_a].contains(i)).count();
    println!(
        "  stale-model attack: {} candidate items, exact recovery {}/{} of A's history",
        candidates.len(),
        hit,
        histories[user_a].len()
    );
    println!("  => deleting raw data alone does NOT protect user A");

    println!("\n== step 3: DEAL's remedy — the model itself forgets ==");
    // once every worker has applied FORGET, no stale model remains: a new
    // attacker snapshot diffs two identical post-forget models
    let now = after.dense_similarity();
    let leak_after = recover_deleted_items(&now, &after.dense_similarity(), 1e-7);
    println!(
        "  post-forget attack recovers {} items — the trace is gone",
        leak_after.len()
    );
    assert!(leak_after.is_empty());

    // and the model still works for everyone else
    let other = &histories[5];
    let recs = after.predict(&other[..other.len() - 1], 5);
    println!(
        "  model still serves user 5: top-5 recommendations {:?}",
        recs.iter().map(|&(i, _)| i).collect::<Vec<_>>()
    );
}
