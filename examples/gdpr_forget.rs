//! The paper's Fig. 1 privacy story, end to end:
//!
//! 1. Build a PPR model over a Retailrocket-style event log.
//! 2. User A invokes GDPR deletion; the *raw events* are removed — but a
//!    stale similarity model still leaks A's history (similar users B/C,
//!    matrix diffing).
//! 3. DEAL's remedy: FORGET the user from the model itself (Alg. 1),
//!    after which the leak is gone.
//! 4. The same remedy, *live*: a stream of GDPR requests is replayed
//!    into a running `Federation` — the `coordinator::unlearn` pipeline
//!    routes `ForgetCommand`s to the devices holding the victims' data,
//!    the forget guard vets each one, and every ack carries a recovery-
//!    attack audit proving the datum is out of the live model.
//!
//!     cargo run --release --example gdpr_forget

use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::Scheme;
use deal::data::events::{gdpr_requests, generate_events};
use deal::data::Dataset;
use deal::learn::recovery::{recover_deleted_items, recover_deleted_items_exact};
use deal::learn::{DecrementalModel, NullMiddleware, Ppr};

fn main() {
    // Retailrocket-shaped log: cohorts of users with shared tastes
    let log = generate_events(2026, 80, 400, 4, 50);
    let histories = log.user_histories();
    let user_a = 0usize;

    println!("== step 1: the service trains a PPR similarity model ==");
    let model = Ppr::fit(log.items, 10, &histories);
    println!(
        "  {} users, {} items, user A has {} interactions",
        log.users,
        log.items,
        histories[user_a].len()
    );

    // find A's most similar users (the paper's B and C)
    let mut sims: Vec<(usize, f64)> = (0..log.users)
        .filter(|&u| u != user_a)
        .map(|u| (u, log.user_jaccard(&histories[user_a], &histories[u])))
        .collect();
    sims.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!(
        "  most similar users to A: B=user{} ({:.2}), C=user{} ({:.2})",
        sims[0].0, sims[0].1, sims[1].0, sims[1].1
    );

    println!("\n== step 2: user A deletes their data (GDPR) — raw events only ==");
    let stale_sim = model.dense_similarity();
    let stale_counts = model.counts().to_vec();
    let mut after = model.clone();
    let mut mw = NullMiddleware;
    after.forget(&histories[user_a], &mut mw);

    // the attacker holds the stale model and observes the fresh one
    let candidates = recover_deleted_items(&stale_sim, &after.dense_similarity(), 1e-7);
    let exact = recover_deleted_items_exact(&stale_counts, after.counts());
    let hit = exact.iter().filter(|i| histories[user_a].contains(i)).count();
    println!(
        "  stale-model attack: {} candidate items, exact recovery {}/{} of A's history",
        candidates.len(),
        hit,
        histories[user_a].len()
    );
    println!("  => deleting raw data alone does NOT protect user A");

    println!("\n== step 3: DEAL's remedy — the model itself forgets ==");
    // once every worker has applied FORGET, no stale model remains: a new
    // attacker snapshot diffs two identical post-forget models
    let now = after.dense_similarity();
    let leak_after = recover_deleted_items(&now, &after.dense_similarity(), 1e-7);
    println!(
        "  post-forget attack recovers {} items — the trace is gone",
        leak_after.len()
    );
    assert!(leak_after.is_empty());

    // and the model still works for everyone else
    let other = &histories[5];
    let recs = after.predict(&other[..other.len() - 1], 5);
    println!(
        "  model still serves user 5: top-5 recommendations {:?}",
        recs.iter().map(|&(i, _)| i).collect::<Vec<_>>()
    );

    federated_replay(&log);
}

/// Step 4: the same deletion story through a *live* federation — the
/// coordinator→transport→device unlearning pipeline, with the guard and
/// the post-ack audit in the loop.
fn federated_replay(log: &deal::data::events::EventLog) {
    println!("\n== step 4: GDPR requests replayed through a live Federation ==");
    let mut fed = fleet::build(&FleetConfig {
        n_devices: 8,
        dataset: Dataset::Movielens,
        scale: 0.05,
        scheme: Scheme::Deal,
        seed: 2026,
        deletion_slo: 2,
        ..FleetConfig::default()
    });
    // warm the fleet: a few rounds of live training before deletions land
    for _ in 0..5 {
        fed.run_round();
    }
    // the event log's GDPR stream, mapped onto the fleet: user u's data
    // lives on device u mod n as (prefilled, i.e. absorbed) datum
    let requests = gdpr_requests(log, 7, 12);
    let n = fed.n_devices();
    for r in &requests {
        let device = r.user as usize % n;
        let absorbed = ((fed.transport().shard_len(device) as f64) * 0.5) as usize;
        let datum = r.user as usize / n % absorbed.max(1);
        fed.submit_deletion(device, datum);
    }
    println!(
        "  {} deletion requests submitted against {} devices (SLO: 2 rounds)",
        requests.len(),
        n
    );
    let mut rounds = 0;
    while fed.unlearn().pending() > 0 && rounds < 40 {
        fed.run_round();
        rounds += 1;
    }
    let u = fed.stats().unlearn;
    let audits = fed
        .unlearn()
        .log()
        .iter()
        .filter(|rec| rec.status.completes() && rec.audit_pass)
        .count();
    println!(
        "  after {rounds} rounds: {}/{} served (p50 {:.1} / p99 {:.1} rounds to forget, \
         {} SLO wakeups, {} guard denials)",
        u.served, u.submitted, u.rounds_to_forget_p50, u.rounds_to_forget_p99,
        u.overdue_wakeups, u.guard_denials,
    );
    println!(
        "  post-ack audit: {audits}/{} recovery-attack checks passed — every served \
         datum is verifiably out of its live model",
        u.served
    );
    assert_eq!(u.served, u.submitted, "every request must be served");
    assert_eq!(audits as u64, u.served, "every audit must pass");
}
