//! Quickstart: stand up a DEAL federation on synthetic MovieLens and run
//! a few rounds.
//!
//!     cargo run --release --example quickstart
//!
//! Shows the three moving parts of the public API: a [`FleetConfig`]
//! describing the experiment, [`fleet::build`] creating the federation
//! (devices + MAB selector), and per-round records coming back.

use deal::coordinator::fleet::{self, FleetConfig};
use deal::coordinator::Scheme;
use deal::data::Dataset;
use deal::util::tables::fmt_uah;

fn main() {
    let cfg = FleetConfig {
        n_devices: 12,
        dataset: Dataset::Movielens,
        scale: 0.05, // 5% of the published row count for a fast demo
        scheme: Scheme::Deal,
        theta: 0.3, // forget 30% of each round's data volume
        m: 4,       // at most 4 workers per round
        seed: 42,
        ..FleetConfig::default()
    };
    println!(
        "DEAL quickstart: {} devices on {}, m={}, θ={}",
        cfg.n_devices,
        cfg.dataset.name(),
        cfg.m,
        cfg.theta
    );

    let mut fed = fleet::build(&cfg);
    for _ in 0..15 {
        let r = fed.run_round();
        println!(
            "round {:>2}: available {:>2}, selected {}, round time {:>7.3}s, \
             energy {:>12}, mean accuracy {:.3}",
            r.round,
            r.available,
            r.selected,
            r.round_time_s,
            fmt_uah(r.energy_uah),
            r.mean_accuracy,
        );
    }

    let stats = fed.stats();
    println!(
        "\nsummary: {} rounds, {:.2}s virtual time, {} total energy, \
         {}/{} devices converged",
        stats.rounds,
        stats.total_time_s,
        fmt_uah(stats.total_energy_uah),
        stats.converged_devices,
        fed.n_devices(),
    );
}
